//! Engine-free property tests of the packed wire layer
//! (`compression/wire.rs`): pack → unpack must reproduce the in-memory
//! payload bit-for-bit for all four schemes, and the packed buffer
//! length must equal the closed-form `wire_bytes` accounting for
//! ternary and HCFL (the formulas the clock layer used before wire
//! sizes were measured).

use hcfl::compression::hcfl::hcfl_wire_bytes;
use hcfl::compression::wire::{
    self, HcflWireLayout, RangeLayout, WireScratch,
};
use hcfl::compression::{
    ChunkCode, Compressor, Payload, RangeCodes, TernaryChunk, TernaryCompressor,
    TopKCompressor,
};
use hcfl::model::SegmentRange;
use hcfl::util::rng::Rng;

fn random_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

#[test]
fn raw_pack_unpack_is_bit_identical() {
    let mut rng = Rng::new(1);
    for _ in 0..20 {
        let d = 1 + rng.below(4000);
        let v = random_vec(&mut rng, d, 0.7);
        let payload = Payload::Raw(v.clone());
        let mut scratch = WireScratch::new();
        let len = scratch.pack(&payload).unwrap();
        assert_eq!(len, 4 * d); // identical to Identity's wire_bytes
        assert_eq!(wire::unpack_raw(scratch.bytes(), d).unwrap(), v);
    }
}

#[test]
fn ternary_pack_unpack_matches_payload_and_formula() {
    let chunk = 1024;
    let mut rng = Rng::new(2);
    for case in 0..20 {
        let d = 1 + rng.below(20_000);
        let v = random_vec(&mut rng, d, 0.3);
        let chunks: Vec<TernaryChunk> = v
            .chunks(chunk)
            .map(TernaryCompressor::quantize_ref)
            .collect();
        let payload = Payload::TernaryChunks(chunks.clone());
        let mut scratch = WireScratch::new();
        let len = scratch.pack(&payload).unwrap();
        // packed length equals the closed-form accounting
        assert_eq!(
            len,
            TernaryCompressor::wire_bytes_for(d, chunk),
            "case {case}: d={d}"
        );
        // round trip is bit-identical to the in-memory payload path
        let back = wire::unpack_ternary(scratch.bytes(), d, chunk).unwrap();
        assert_eq!(back.len(), chunks.len());
        for (a, b) in chunks.iter().zip(&back) {
            assert_eq!(a.q, b.q, "case {case}");
            assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "case {case}");
        }
        // and the decoded vectors agree exactly
        assert_eq!(
            TernaryCompressor::decode_chunks(&chunks, d).unwrap(),
            TernaryCompressor::decode_chunks(&back, d).unwrap()
        );
    }
}

/// Build a synthetic HCFL payload with the exact geometry the codec
/// produces (full-length codes, 16 B side info per chunk).
fn fake_hcfl_payload(
    rng: &mut Rng,
    ranges: &[(usize, usize)], // (n_chunks, code_len) per range
) -> (Payload, HcflWireLayout) {
    let mut codes = Vec::new();
    let mut layouts = Vec::new();
    for (ri, &(n_chunks, code_len)) in ranges.iter().enumerate() {
        let chunks: Vec<ChunkCode> = (0..n_chunks)
            .map(|_| ChunkCode {
                code: random_vec(rng, code_len, 1.0),
                lo: rng.normal(),
                hi: rng.normal(),
                mu: rng.normal(),
                sd: rng.normal().abs(),
            })
            .collect();
        codes.push(RangeCodes {
            range_idx: ri,
            chunks,
        });
        layouts.push(RangeLayout {
            range_idx: ri,
            n_chunks,
            code_len,
        });
    }
    (Payload::HcflCodes(codes), HcflWireLayout { ranges: layouts })
}

#[test]
fn hcfl_pack_unpack_matches_payload_and_formula() {
    let mut rng = Rng::new(3);
    // LeNet-ish geometry: 11 conv chunks of c256 at 1:8, 41 dense of
    // c1024 at 1:8
    let (payload, layout) = fake_hcfl_payload(&mut rng, &[(11, 32), (41, 128)]);
    let mut scratch = WireScratch::new();
    let len = scratch.pack(&payload).unwrap();
    assert_eq!(len, layout.packed_len());

    // the layout-derived length equals the closed-form hcfl_wire_bytes
    // for the equivalent segment ranges
    let ranges = vec![
        SegmentRange {
            segment: "conv".into(),
            label: "conv".into(),
            offset: 0,
            len: 11 * 256 - 100, // padded tail chunk, same chunk count
        },
        SegmentRange {
            segment: "dense".into(),
            label: "dense".into(),
            offset: 11 * 256 - 100,
            len: 40 * 1024 + 1,
        },
    ];
    let chunk_of_segment: std::collections::BTreeMap<String, usize> =
        [("conv".to_string(), 256), ("dense".to_string(), 1024)]
            .into_iter()
            .collect();
    assert_eq!(len, hcfl_wire_bytes(&ranges, &chunk_of_segment, 8));

    // bit-identical round trip
    let back = wire::unpack_hcfl(scratch.bytes(), &layout).unwrap();
    let Payload::HcflCodes(orig) = &payload else {
        unreachable!()
    };
    assert_eq!(back.len(), orig.len());
    for (a, b) in orig.iter().zip(&back) {
        assert_eq!(a.range_idx, b.range_idx);
        assert_eq!(a.chunks.len(), b.chunks.len());
        for (ca, cb) in a.chunks.iter().zip(&b.chunks) {
            assert_eq!(ca.code, cb.code);
            assert_eq!(ca.lo.to_bits(), cb.lo.to_bits());
            assert_eq!(ca.hi.to_bits(), cb.hi.to_bits());
            assert_eq!(ca.mu.to_bits(), cb.mu.to_bits());
            assert_eq!(ca.sd.to_bits(), cb.sd.to_bits());
        }
    }

    // truncated buffers are rejected
    assert!(wire::unpack_hcfl(&scratch.bytes()[..len - 1], &layout).is_err());
}

#[test]
fn sparse_pack_unpack_is_bit_identical_and_beats_formula() {
    let mut rng = Rng::new(4);
    for case in 0..20 {
        let d = 50 + rng.below(30_000);
        let keep = 0.05 + rng.next_f64() * 0.4;
        let c = TopKCompressor::new(keep).unwrap();
        let v = random_vec(&mut rng, d, 1.0);
        let upd = c.compress(&v, 0).unwrap();
        let k = c.k_for(d);
        let mut scratch = WireScratch::new();
        let len = scratch.pack(&upd.payload).unwrap();
        // delta varints make the measured size beat the old 8k formula
        assert!(len < 8 * k + 8, "case {case}: {len} vs {}", 8 * k);
        let back = wire::unpack_sparse(scratch.bytes()).unwrap();
        let (Payload::Sparse { d: d0, idx: i0, val: v0 }, Payload::Sparse { d: d1, idx: i1, val: v1 }) =
            (&upd.payload, &back)
        else {
            unreachable!()
        };
        assert_eq!(d0, d1);
        assert_eq!(i0, i1);
        assert_eq!(
            v0.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            v1.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
}
