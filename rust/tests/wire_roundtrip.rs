//! Engine-free property tests of the packed wire layer
//! (`compression/wire.rs`): pack → unpack must reproduce the in-memory
//! payload bit-for-bit for all four schemes, and the packed buffer
//! length must equal the closed-form `wire_bytes` accounting for
//! ternary and HCFL (the formulas the clock layer used before wire
//! sizes were measured).

use hcfl::compression::hcfl::hcfl_wire_bytes;
use hcfl::compression::wire::{
    self, HcflWireLayout, RangeLayout, WireScratch,
};
use hcfl::compression::{
    Compressor, Identity, Payload, RangeCodes, TernaryChunk, TernaryCompressor,
    TopKCompressor,
};
use hcfl::model::SegmentRange;
use hcfl::util::rng::Rng;

fn random_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

#[test]
fn raw_pack_unpack_is_bit_identical() {
    let mut rng = Rng::new(1);
    for _ in 0..20 {
        let d = 1 + rng.below(4000);
        let v = random_vec(&mut rng, d, 0.7);
        let payload = Payload::Raw(v.clone());
        let mut scratch = WireScratch::new();
        let len = scratch.pack(&payload).unwrap();
        assert_eq!(len, 4 * d); // identical to Identity's wire_bytes
        assert_eq!(wire::unpack_raw(scratch.bytes(), d).unwrap(), v);
    }
}

#[test]
fn ternary_pack_unpack_matches_payload_and_formula() {
    let chunk = 1024;
    let mut rng = Rng::new(2);
    for case in 0..20 {
        let d = 1 + rng.below(20_000);
        let v = random_vec(&mut rng, d, 0.3);
        let chunks: Vec<TernaryChunk> = v
            .chunks(chunk)
            .map(TernaryCompressor::quantize_ref)
            .collect();
        let payload = Payload::TernaryChunks(chunks.clone());
        let mut scratch = WireScratch::new();
        let len = scratch.pack(&payload).unwrap();
        // packed length equals the closed-form accounting
        assert_eq!(
            len,
            TernaryCompressor::wire_bytes_for(d, chunk),
            "case {case}: d={d}"
        );
        // round trip is bit-identical to the in-memory payload path
        let back = wire::unpack_ternary(scratch.bytes(), d, chunk).unwrap();
        assert_eq!(back.len(), chunks.len());
        for (a, b) in chunks.iter().zip(&back) {
            assert_eq!(a.q, b.q, "case {case}");
            assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "case {case}");
        }
        // and the decoded vectors agree exactly
        assert_eq!(
            TernaryCompressor::decode_chunks(&chunks, d).unwrap(),
            TernaryCompressor::decode_chunks(&back, d).unwrap()
        );
    }
}

/// Build a synthetic HCFL payload with the exact geometry the codec
/// produces (full-length codes, 16 B side info per chunk).  The random
/// draws happen in per-chunk order — code row, then lo/hi/mu/sd — so
/// the values (and therefore the packed bytes) are unchanged from the
/// pre-SoA chunk-of-structs builder.
fn fake_hcfl_payload(
    rng: &mut Rng,
    ranges: &[(usize, usize)], // (n_chunks, code_len) per range
) -> (Payload, HcflWireLayout) {
    let mut codes = Vec::new();
    let mut layouts = Vec::new();
    for (ri, &(n_chunks, code_len)) in ranges.iter().enumerate() {
        let mut rc = RangeCodes::with_capacity(ri, code_len, n_chunks);
        for _ in 0..n_chunks {
            rc.codes.extend(random_vec(rng, code_len, 1.0));
            rc.lo.push(rng.normal());
            rc.hi.push(rng.normal());
            rc.mu.push(rng.normal());
            rc.sd.push(rng.normal().abs());
        }
        codes.push(rc);
        layouts.push(RangeLayout {
            range_idx: ri,
            n_chunks,
            code_len,
        });
    }
    (Payload::HcflCodes(codes), HcflWireLayout { ranges: layouts })
}

#[test]
fn hcfl_pack_unpack_matches_payload_and_formula() {
    let mut rng = Rng::new(3);
    // LeNet-ish geometry: 11 conv chunks of c256 at 1:8, 41 dense of
    // c1024 at 1:8
    let (payload, layout) = fake_hcfl_payload(&mut rng, &[(11, 32), (41, 128)]);
    let mut scratch = WireScratch::new();
    let len = scratch.pack(&payload).unwrap();
    assert_eq!(len, layout.packed_len());

    // the layout-derived length equals the closed-form hcfl_wire_bytes
    // for the equivalent segment ranges
    let ranges = vec![
        SegmentRange {
            segment: "conv".into(),
            label: "conv".into(),
            offset: 0,
            len: 11 * 256 - 100, // padded tail chunk, same chunk count
        },
        SegmentRange {
            segment: "dense".into(),
            label: "dense".into(),
            offset: 11 * 256 - 100,
            len: 40 * 1024 + 1,
        },
    ];
    let chunk_of_segment: std::collections::BTreeMap<String, usize> =
        [("conv".to_string(), 256), ("dense".to_string(), 1024)]
            .into_iter()
            .collect();
    assert_eq!(len, hcfl_wire_bytes(&ranges, &chunk_of_segment, 8));

    // bit-identical round trip
    let back = wire::unpack_hcfl(scratch.bytes(), &layout).unwrap();
    let Payload::HcflCodes(orig) = &payload else {
        unreachable!()
    };
    assert_eq!(back.len(), orig.len());
    let f32_bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    for (a, b) in orig.iter().zip(&back) {
        assert_eq!(a.range_idx, b.range_idx);
        assert_eq!(a.code_len, b.code_len);
        assert_eq!(a.n_chunks(), b.n_chunks());
        assert_eq!(f32_bits(&a.codes), f32_bits(&b.codes));
        assert_eq!(f32_bits(&a.lo), f32_bits(&b.lo));
        assert_eq!(f32_bits(&a.hi), f32_bits(&b.hi));
        assert_eq!(f32_bits(&a.mu), f32_bits(&b.mu));
        assert_eq!(f32_bits(&a.sd), f32_bits(&b.sd));
    }

    // truncated buffers are rejected
    assert!(wire::unpack_hcfl(&scratch.bytes()[..len - 1], &layout).is_err());
}

#[test]
fn sparse_pack_unpack_is_bit_identical_and_beats_formula() {
    let mut rng = Rng::new(4);
    for case in 0..20 {
        let d = 50 + rng.below(30_000);
        let keep = 0.05 + rng.next_f64() * 0.4;
        let c = TopKCompressor::new(keep).unwrap();
        let v = random_vec(&mut rng, d, 1.0);
        let upd = c.compress(&v, 0).unwrap();
        let k = c.k_for(d);
        let mut scratch = WireScratch::new();
        let len = scratch.pack(&upd.payload).unwrap();
        // delta varints make the measured size beat the old 8k formula
        assert!(len < 8 * k + 8, "case {case}: {len} vs {}", 8 * k);
        let back = wire::unpack_sparse(scratch.bytes()).unwrap();
        let (Payload::Sparse { d: d0, idx: i0, val: v0 }, Payload::Sparse { d: d1, idx: i1, val: v1 }) =
            (&upd.payload, &back)
        else {
            unreachable!()
        };
        assert_eq!(d0, d1);
        assert_eq!(i0, i1);
        assert_eq!(
            v0.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            v1.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
}

// ---------------------------------------------------------------------------
// Hardened varint decoding (the sparse index stream's parser)
// ---------------------------------------------------------------------------

#[test]
fn varint_accepts_every_canonical_boundary() {
    // (encoding, value) pairs at each width boundary, u32::MAX included
    let cases: &[(&[u8], u32)] = &[
        (&[0x00], 0),
        (&[0x7F], 127),
        (&[0x80, 0x01], 128),
        (&[0xAC, 0x02], 300),
        (&[0xFF, 0x7F], 16_383),
        (&[0x80, 0x80, 0x01], 16_384),
        (&[0xFF, 0xFF, 0xFF, 0xFF, 0x0F], u32::MAX),
    ];
    for (bytes, want) in cases {
        let mut pos = 0usize;
        assert_eq!(wire::read_varint(bytes, &mut pos).unwrap(), *want);
        assert_eq!(pos, bytes.len(), "cursor must land past {want}");
    }
}

#[test]
fn varint_rejects_truncated_overlong_and_overflowing_encodings() {
    let bad: &[(&[u8], &str)] = &[
        (&[], "truncated"),
        (&[0x80], "truncated"),
        (&[0x80, 0x80, 0x80, 0x80], "truncated"),
        // 5th byte carries bits 32+ of the value
        (&[0xFF, 0xFF, 0xFF, 0xFF, 0x10], "overflows"),
        (&[0xFF, 0xFF, 0xFF, 0xFF, 0x7F], "overflows"),
        // continuation past the 5th byte
        (&[0x80, 0x80, 0x80, 0x80, 0x80, 0x01], "overflows"),
        // overlong: trailing zero payload bytes encode the value
        // non-minimally (our packer never emits these)
        (&[0x80, 0x00], "overlong"),
        (&[0xFF, 0x80, 0x00], "overlong"),
    ];
    for (bytes, needle) in bad {
        let mut pos = 0usize;
        let err = wire::read_varint(bytes, &mut pos).unwrap_err();
        assert!(
            err.to_string().contains(needle),
            "{bytes:02X?}: expected {needle}, got {err}"
        );
    }
}

#[test]
fn sparse_unpack_rejects_forged_headers_without_allocating() {
    // a forged k near u32::MAX must be rejected by the length guard
    // before any index buffer is sized from it
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&(1_000u32).to_le_bytes()); // d
    bytes.extend_from_slice(&(u32::MAX).to_le_bytes()); // k
    bytes.extend_from_slice(&[0x01; 32]);
    let err = wire::unpack_sparse(&bytes).unwrap_err();
    assert!(err.to_string().contains("too short for k="), "{err}");
}

// ---------------------------------------------------------------------------
// Zero-copy decode (`unpack_into`) vs the structured reference path
// ---------------------------------------------------------------------------
//
// HCFL's `unpack_into` shares this contract but needs the AE engine to
// decode; its engine-backed twin lives in `compression_pipeline.rs`,
// while its wire parse is pinned bit-exactly above.

#[test]
fn identity_unpack_into_is_bit_identical_to_decompress() {
    let mut rng = Rng::new(7);
    let mut scratch = WireScratch::new();
    for _ in 0..10 {
        let d = 1 + rng.below(5000);
        let v = random_vec(&mut rng, d, 0.8);
        let upd = Identity.compress(&v, 0).unwrap();
        let wire_upd = scratch.pack_update(&upd.payload).unwrap();
        let reference = Identity.decompress(upd, d, 0).unwrap();
        let mut out = Vec::new();
        Identity
            .unpack_into(&wire_upd.bytes, d, 0, &mut scratch, &mut out)
            .unwrap();
        assert_eq!(
            out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            reference.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
}

#[test]
fn topk_unpack_into_is_bit_identical_to_decompress() {
    let mut rng = Rng::new(8);
    let mut scratch = WireScratch::new();
    for _ in 0..10 {
        let d = 50 + rng.below(20_000);
        let c = TopKCompressor::new(0.05 + rng.next_f64() * 0.3).unwrap();
        let v = random_vec(&mut rng, d, 1.0);
        let upd = c.compress(&v, 0).unwrap();
        let wire_upd = scratch.pack_update(&upd.payload).unwrap();
        let reference = c.decompress(upd, d, 0).unwrap();
        let mut out = Vec::new();
        c.unpack_into(&wire_upd.bytes, d, 0, &mut scratch, &mut out)
            .unwrap();
        assert_eq!(
            out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            reference.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
}

#[test]
fn ternary_unpack_into_is_bit_identical_to_structured_decode() {
    let chunk = 1024;
    let mut rng = Rng::new(9);
    for _ in 0..10 {
        // deliberately not chunk-aligned: the final chunk exercises the
        // scalar bit-offset tail of `unpack_ternary_into`
        let d = 1 + rng.below(10_000);
        let v = random_vec(&mut rng, d, 0.4);
        let chunks: Vec<TernaryChunk> = v
            .chunks(chunk)
            .map(TernaryCompressor::quantize_ref)
            .collect();
        let mut scratch = WireScratch::new();
        scratch.pack(&Payload::TernaryChunks(chunks.clone())).unwrap();
        // structured reference: parse chunks, then dequantize per Vec
        let parsed = wire::unpack_ternary(scratch.bytes(), d, chunk).unwrap();
        let reference = TernaryCompressor::decode_chunks(&parsed, d).unwrap();
        // zero-copy: straight into the flat output
        let mut out = Vec::new();
        wire::unpack_ternary_into(scratch.bytes(), d, chunk, &mut out).unwrap();
        assert_eq!(
            out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            reference.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
}

#[test]
fn ternary_unpack_rejects_nonzero_tail_padding() {
    // d % 4 != 0 leaves padding bits in the final byte; a forger setting
    // them must be caught (zero-copy and structured paths agree)
    let d = 1027;
    let chunk = 1024;
    let v: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin()).collect();
    let chunks: Vec<TernaryChunk> = v
        .chunks(chunk)
        .map(TernaryCompressor::quantize_ref)
        .collect();
    let mut scratch = WireScratch::new();
    let len = scratch.pack(&Payload::TernaryChunks(chunks)).unwrap();
    let mut bytes = scratch.bytes().to_vec();
    assert_eq!(bytes.len(), len);
    *bytes.last_mut().unwrap() |= 0b11 << 6; // poison the padding lanes
    let mut out = Vec::new();
    assert!(wire::unpack_ternary_into(&bytes, d, chunk, &mut out).is_err());
    assert!(wire::unpack_ternary(&bytes, d, chunk).is_err());
}
